//! Continuous-migrator sweep: threshold pairs × concurrent-transfer
//! budgets × actuation backends on a bursty decaying synthetic trace,
//! against migrator-off baselines. Each cell replays the same seeded
//! trace through `run_trace` with the migration manager consolidating
//! the fleet as load drains, and reports the cluster-scope ledger —
//! parked-aware energy (Wh), overload-time SLAV, active host-hours —
//! plus the time-to-converge (powered-host peak to half-drain) and the
//! usual sustained events/sec.
//!
//! Full mode runs 256 hosts with 40k trace events; `VMCD_BENCH_QUICK=1`
//! shrinks to 32 hosts × 4k events for CI. Replays are measured once
//! end-to-end (no iteration harness). A second sweep replays a diurnal
//! sawtooth trace under myopic vs forecast+payback planning × linear vs
//! piecewise power, recording the churn and energy the predictive
//! planner saves. Emits `BENCH_migrator.json`.

mod common;

use vmcd::cluster::trace::synth::SyntheticTraceGenerator;
use vmcd::cluster::{ClusterSpec, StepMode, Strategy};
use vmcd::config::{MigratorParams, PowerModel};
use vmcd::scenarios::run_trace;
use vmcd::util::json::Json;
use vmcd::vmcd::ActuationSpec;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let quick = std::env::var("VMCD_BENCH_QUICK").as_deref() == Ok("1");

    // A burst-heavy trace whose working set decays as lifetimes expire:
    // the regime where parking pays and convergence is measurable.
    let (hosts, synth_spec): (usize, &str) = if quick {
        (32, "vms=2000,rate=80,burst=8,life=40,lmax=200,seed=42")
    } else {
        (256, "vms=20000,rate=200,burst=16,life=60,lmax=400,seed=42")
    };
    let thresholds: &[(f64, f64)] = if quick {
        &[(0.85, 0.35)]
    } else {
        &[(0.85, 0.35), (0.90, 0.25), (0.75, 0.45)]
    };
    let budgets: &[usize] = if quick { &[4] } else { &[2, 8] };
    let actuations = [
        ("inline", ActuationSpec::Inline),
        (
            "deferred4b32",
            ActuationSpec::Deferred {
                latency_ticks: 4,
                budget_per_tick: 32,
            },
        ),
    ];

    // Every cell up front: per actuation, one migrator-off baseline plus
    // the threshold × budget sweep.
    let mut combos: Vec<(Option<MigratorParams>, &str, ActuationSpec)> = Vec::new();
    for (act_name, actuation) in actuations {
        combos.push((None, act_name, actuation));
        for &(over, under) in thresholds {
            for &budget in budgets {
                let params = MigratorParams {
                    over,
                    under,
                    budget,
                    ..Default::default()
                };
                combos.push((Some(params), act_name, actuation));
            }
        }
    }

    println!(
        "{:<12} {:>6} {:<12} {:>6} {:>10} {:>8} {:>9} {:>10} {:>12}",
        "over/under",
        "budget",
        "actuation",
        "moves",
        "energy Wh",
        "SLAV",
        "converge",
        "hosthours",
        "events/sec"
    );
    let mut rows: Vec<Json> = Vec::new();
    for (migrator, act_name, actuation) in combos {
        let mut spec = ClusterSpec::new(hosts, Strategy::LocalVmcd);
        spec.cfg = cfg.clone();
        spec.step_mode = StepMode::Pool(4);
        spec.actuation = actuation;
        spec.migrator = migrator.clone();
        let mut reader = SyntheticTraceGenerator::parse(synth_spec, 42)?;
        let r = run_trace(&spec, &mut reader, &bank)?;
        anyhow::ensure!(!r.truncated, "migrator bench hit max_time");
        let (label, over, under, budget) = match &migrator {
            Some(m) => (format!("{:.2}/{:.2}", m.over, m.under), m.over, m.under, m.budget),
            None => ("off".to_string(), 0.0, 0.0, 0),
        };
        let converge = match r.converge_ticks {
            Some(t) => t.to_string(),
            None => "-".to_string(),
        };
        println!(
            "{:<12} {:>6} {:<12} {:>6} {:>10.1} {:>8.4} {:>9} {:>10.2} {:>12.0}",
            label,
            budget,
            act_name,
            r.migrator_moves,
            r.energy_wh,
            r.slav,
            converge,
            r.active_host_hours,
            r.events_per_sec()
        );
        rows.push(Json::from_pairs(vec![
            ("migrator", Json::Bool(migrator.is_some())),
            ("over", Json::Num(over)),
            ("under", Json::Num(under)),
            ("budget", Json::Num(budget as f64)),
            ("actuation", Json::Str(act_name.into())),
            ("hosts", Json::Num(hosts as f64)),
            ("events", Json::Num((r.arrivals + r.departures + r.migrates) as f64)),
            ("ticks", Json::Num(r.ticks as f64)),
            ("migrator_moves", Json::Num(r.migrator_moves as f64)),
            ("migrations_started", Json::Num(r.migrations_started as f64)),
            ("migrations_completed", Json::Num(r.migrations_completed as f64)),
            ("migrations_failed", Json::Num(r.migrations_failed as f64)),
            ("core_hours", Json::Num(r.core_hours)),
            ("energy_wh", Json::Num(r.energy_wh)),
            ("plugged_energy_wh", Json::Num(r.plugged_energy_wh)),
            ("slav", Json::Num(r.slav)),
            ("overload_seconds", Json::Num(r.overload_seconds)),
            ("active_host_hours", Json::Num(r.active_host_hours)),
            (
                "converge_ticks",
                r.converge_ticks.map_or(Json::Null, |t| Json::Num(t as f64)),
            ),
            ("wall_ms", Json::Num(r.wall.as_secs_f64() * 1e3)),
            ("events_per_sec", Json::Num(r.events_per_sec())),
        ]));
    }

    // Sawtooth sweep: a diurnal trace whose load dips below the park
    // line every period and climbs back out — the park/unpark thrash
    // regime. Myopic vs forecast+payback planning, each under the
    // linear power law and a convex SPECpower-style piecewise table,
    // so the rows record how much migration churn and energy the
    // predictive planner saves on the same event stream.
    let (saw_hosts, saw_spec): (usize, &str) = if quick {
        (32, "vms=2000,rate=80,burst=8,life=40,lmax=200,diurnal=0.9,period=120,seed=42")
    } else {
        (256, "vms=20000,rate=200,burst=16,life=60,lmax=400,diurnal=0.9,period=300,seed=42")
    };
    let planners = [
        ("myopic", "0.7:0.3:8:15,cooldown=30"),
        (
            "forecast",
            "0.7:0.3:8:15,cooldown=30,forecast=on,alpha=0.3,beta=0.05,horizon=20,k=3,payback=600",
        ),
    ];
    let powers = [("linear", "linear"), ("piecewise", "piecewise:0=58,0.5=150,1=280")];
    println!(
        "\n{:<12} {:<12} {:>6} {:>10} {:>10} {:>8} {:>12}",
        "planner", "power", "moves", "started", "energy Wh", "SLAV", "events/sec"
    );
    for (planner, migrator_spec) in planners {
        for (power_name, power_spec) in powers {
            let mut spec = ClusterSpec::new(saw_hosts, Strategy::LocalVmcd);
            spec.cfg = cfg.clone();
            spec.cfg.power = PowerModel::parse(power_spec)?;
            spec.step_mode = StepMode::Pool(4);
            spec.migrator = Some(MigratorParams::parse(migrator_spec)?);
            let mut reader = SyntheticTraceGenerator::parse(saw_spec, 42)?;
            let r = run_trace(&spec, &mut reader, &bank)?;
            anyhow::ensure!(!r.truncated, "sawtooth bench hit max_time");
            println!(
                "{:<12} {:<12} {:>6} {:>10} {:>10.1} {:>8.4} {:>12.0}",
                planner,
                power_name,
                r.migrator_moves,
                r.migrations_started,
                r.energy_wh,
                r.slav,
                r.events_per_sec()
            );
            rows.push(Json::from_pairs(vec![
                ("scenario", Json::Str("sawtooth".into())),
                ("planner", Json::Str(planner.into())),
                ("power", Json::Str(power_name.into())),
                ("migrator_spec", Json::Str(migrator_spec.into())),
                ("hosts", Json::Num(saw_hosts as f64)),
                ("events", Json::Num((r.arrivals + r.departures + r.migrates) as f64)),
                ("ticks", Json::Num(r.ticks as f64)),
                ("migrator_moves", Json::Num(r.migrator_moves as f64)),
                ("migrations_started", Json::Num(r.migrations_started as f64)),
                ("migrations_completed", Json::Num(r.migrations_completed as f64)),
                ("migrations_failed", Json::Num(r.migrations_failed as f64)),
                ("core_hours", Json::Num(r.core_hours)),
                ("energy_wh", Json::Num(r.energy_wh)),
                ("plugged_energy_wh", Json::Num(r.plugged_energy_wh)),
                ("slav", Json::Num(r.slav)),
                ("overload_seconds", Json::Num(r.overload_seconds)),
                ("active_host_hours", Json::Num(r.active_host_hours)),
                (
                    "converge_ticks",
                    r.converge_ticks.map_or(Json::Null, |t| Json::Num(t as f64)),
                ),
                ("wall_ms", Json::Num(r.wall.as_secs_f64() * 1e3)),
                ("events_per_sec", Json::Num(r.events_per_sec())),
            ]));
        }
    }

    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("migrator".into())),
        ("synth_spec", Json::Str(synth_spec.into())),
        ("sawtooth_spec", Json::Str(saw_spec.into())),
        ("hosts", Json::Num(hosts as f64)),
        ("quick", Json::Bool(quick)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_migrator.json", doc.pretty() + "\n")?;
    println!(
        "\nwrote BENCH_migrator.json ({} rows)",
        doc.field("rows")?.as_arr().unwrap().len()
    );
    Ok(())
}

//! Fig. 5 — time series of CPU consumption for the 12-job-batch dynamic
//! scenario (paper §V-C.3).

mod common;

use vmcd::bench::Bench;
use vmcd::report;
use vmcd::scenarios::{dynamic, run_scenario};
use vmcd::vmcd::scheduler::Policy;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let seeds = common::seeds();

    let fig = report::fig45(&cfg, &bank, 12, seeds[0])?;
    println!("{}", fig.render());
    fig.write_csv(&common::out_dir())?;

    let mut b = Bench::new();
    b.section("fig5: dynamic-12 scenario simulation time");
    let spec = dynamic::build(12, seeds[0])?;
    for policy in Policy::ALL {
        b.run(&format!("simulate/dynamic12/{}", policy.name()), || {
            run_scenario(&cfg, &spec, policy, &bank).unwrap();
        });
    }
    Ok(())
}

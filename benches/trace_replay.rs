//! Trace replay at cluster scale: the headline end-to-end throughput of
//! the trace subsystem. Each cell streams a seeded synthetic trace
//! (heavy-tailed Poisson-burst arrivals, lognormal lifetimes) through
//! `run_trace` — every arrival is batch-ranked by the dispatcher under
//! test, every departure routed through the event bus, hosts stepped by
//! the persistent shard pool — and reports sustained events/sec.
//!
//! Full mode runs 100k+ VM events (50k arrivals + 50k departures) at
//! 1024 and 4096 hosts per dispatcher; `VMCD_BENCH_QUICK=1` shrinks to
//! 64 hosts × 2k events so CI can afford a smoke pass. Replays are
//! seconds-long, so each cell is measured once end-to-end (no
//! iteration harness). Emits `BENCH_trace.json`.

mod common;

use vmcd::cluster::trace::synth::SyntheticTraceGenerator;
use vmcd::cluster::{ClusterSpec, Dispatcher, StepMode, Strategy};
use vmcd::scenarios::run_trace;
use vmcd::util::json::Json;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let quick = std::env::var("VMCD_BENCH_QUICK").as_deref() == Ok("1");

    // 50k VMs at 100 arrivals/s with 60 s lognormal lifetimes (capped at
    // 600 s) keeps the simulated window near 1100 s while still pushing
    // 100k events through the bus.
    let (fleets, synth_spec): (&[usize], &str) = if quick {
        (&[64], "vms=1000,rate=50,burst=8,life=30,lmax=120,seed=42")
    } else {
        (
            &[1024, 4096],
            "vms=50000,rate=100,burst=8,life=60,lmax=600,seed=42",
        )
    };
    let dispatchers = [
        Dispatcher::LeastLoaded,
        Dispatcher::LowestInterference,
        Dispatcher::DotProduct,
        Dispatcher::PerpDistance,
    ];

    println!(
        "{:<20} {:>6} {:>9} {:>7} {:>10} {:>9} {:>12}",
        "dispatcher", "hosts", "events", "ticks", "peak live", "wall ms", "events/sec"
    );
    let mut rows: Vec<Json> = Vec::new();
    for &hosts in fleets {
        for d in dispatchers {
            let mut spec = ClusterSpec::new(hosts, Strategy::LocalVmcd);
            spec.cfg = cfg.clone();
            spec.dispatcher = d;
            spec.step_mode = StepMode::Pool(4);
            let mut reader = SyntheticTraceGenerator::parse(synth_spec, 42)?;
            let r = run_trace(&spec, &mut reader, &bank)?;
            anyhow::ensure!(!r.truncated, "trace replay hit max_time at {hosts} hosts");
            anyhow::ensure!(
                r.final_live == 0,
                "{} VMs never departed at {hosts} hosts",
                r.final_live
            );
            let events = r.arrivals + r.departures + r.migrates;
            println!(
                "{:<20} {:>6} {:>9} {:>7} {:>10} {:>9} {:>12.0}",
                d.name(),
                hosts,
                events,
                r.ticks,
                r.peak_live,
                r.wall.as_millis(),
                r.events_per_sec()
            );
            rows.push(Json::from_pairs(vec![
                ("dispatcher", Json::Str(d.name().into())),
                ("hosts", Json::Num(hosts as f64)),
                ("events", Json::Num(events as f64)),
                ("arrivals", Json::Num(r.arrivals as f64)),
                ("departures", Json::Num(r.departures as f64)),
                ("ticks", Json::Num(r.ticks as f64)),
                ("peak_live", Json::Num(r.peak_live as f64)),
                ("events_routed", Json::Num(r.events_routed as f64)),
                ("core_hours", Json::Num(r.core_hours)),
                ("energy_wh", Json::Num(r.energy_wh)),
                ("plugged_energy_wh", Json::Num(r.plugged_energy_wh)),
                ("slav", Json::Num(r.slav)),
                ("active_host_hours", Json::Num(r.active_host_hours)),
                ("migrations_completed", Json::Num(r.migrations_completed as f64)),
                ("wall_ms", Json::Num(r.wall.as_secs_f64() * 1e3)),
                ("events_per_sec", Json::Num(r.events_per_sec())),
            ]));
        }
    }

    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("trace_replay".into())),
        ("synth_spec", Json::Str(synth_spec.into())),
        ("quick", Json::Bool(quick)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_trace.json", doc.pretty() + "\n")?;
    println!(
        "\nwrote BENCH_trace.json ({} rows)",
        doc.field("rows")?.as_arr().unwrap().len()
    );
    Ok(())
}

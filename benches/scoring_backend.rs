//! Native vs XLA scoring-backend comparison: per-call latency of the fused
//! all-cores score, and end-to-end scenario agreement.
//!
//! The XLA backend runs the AOT-compiled Pallas kernel through PJRT; the
//! native backend is plain Rust. Decisions must be identical; the bench
//! quantifies the dispatch overhead a PJRT hop costs at this problem size.

mod common;

use vmcd::bench::Bench;
use vmcd::runtime::{Runtime, XlaScoring};
use vmcd::util::rng::Rng;
use vmcd::vmcd::scheduler::{NativeScoring, PlacementState, ScoringBackend};
use vmcd::workloads::ALL_CLASSES;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let mut b = Bench::new();
    b.opts.measure_iters = 30;

    let mut native = NativeScoring::new();
    let rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("XLA runtime unavailable ({e}); run `make artifacts` first");
            return Ok(());
        }
    };
    println!("PJRT platform: {}", rt.platform());
    let mut xla = XlaScoring::new(rt)?;

    for occupancy in [6usize, 24, 48] {
        b.section(&format!("score all cores, {occupancy} resident VMs"));
        let mut rng = Rng::new(42);
        let mut state = PlacementState::new(cfg.host.cores, false);
        for _ in 0..occupancy {
            let core = rng.below(cfg.host.cores);
            state.place(core, *rng.pick(&ALL_CLASSES));
        }
        let cand = ALL_CLASSES[occupancy % ALL_CLASSES.len()];

        b.run(&format!("score/native/occ{occupancy}"), || {
            std::hint::black_box(native.score(&state, cand, &bank, 1.2, false));
        });
        b.run(&format!("score/xla/occ{occupancy}"), || {
            std::hint::black_box(xla.score(&state, cand, &bank, 1.2, false));
        });

        // Agreement check while we are here.
        let a = native.score(&state, cand, &bank, 1.2, false);
        let x = xla.score(&state, cand, &bank, 1.2, false);
        for core in 0..cfg.host.cores {
            assert!((a.ol_after[core] - x.ol_after[core]).abs() < 1e-3);
            assert!((a.ic_after[core] - x.ic_after[core]).abs() < 1e-3);
        }
    }
    println!("\nagreement: native and XLA backends match on all sampled states");
    Ok(())
}

//! Scoring-engine comparison: incremental native vs from-scratch reference
//! vs XLA, as per-call latency of the all-cores score.
//!
//! The incremental engine reads the cached per-core aggregates a
//! `PlacementState::with_bank` state maintains (O(members), zero
//! allocation); the reference re-evaluates Eq. 2–4 from scratch
//! (O(cores × members²)); the XLA backend runs the AOT-compiled Pallas
//! kernel through PJRT. Decisions must be identical across all three;
//! the bench quantifies the incremental speedup and the PJRT dispatch
//! overhead at this problem size.

mod common;

use vmcd::bench::Bench;
use vmcd::runtime::{Runtime, XlaScoring};
use vmcd::util::rng::Rng;
use vmcd::vmcd::scheduler::scoring::reference_scores;
use vmcd::vmcd::scheduler::{NativeScoring, PlacementState, ScoringBackend};
use vmcd::workloads::ALL_CLASSES;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let mut b = Bench::new();
    b.opts.measure_iters = 30;

    let mut native = NativeScoring::new();
    let mut xla = match Runtime::new() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            Some(XlaScoring::new(rt)?)
        }
        Err(e) => {
            eprintln!("XLA runtime unavailable ({e}); comparing native paths only");
            None
        }
    };

    for occupancy in [6usize, 24, 48] {
        b.section(&format!("score all cores, {occupancy} resident VMs"));
        let mut rng = Rng::new(42);
        let mut state = PlacementState::with_bank(cfg.host.cores, false, &bank);
        for _ in 0..occupancy {
            let core = rng.below(cfg.host.cores);
            state.place(core, *rng.pick(&ALL_CLASSES));
        }
        let cand = ALL_CLASSES[occupancy % ALL_CLASSES.len()];

        // The acceptance bar for the incremental engine: ≥ 5× over the
        // from-scratch reference at 12 cores / 48 resident VMs.
        b.run(&format!("score/incremental/occ{occupancy}"), || {
            std::hint::black_box(native.score(&state, cand, &bank, 1.2, false));
        });
        b.run(&format!("score/reference/occ{occupancy}"), || {
            std::hint::black_box(reference_scores(&state, cand, &bank, 1.2, false));
        });
        if let Some(xla) = xla.as_mut() {
            b.run(&format!("score/xla/occ{occupancy}"), || {
                std::hint::black_box(xla.score(&state, cand, &bank, 1.2, false));
            });
        }

        // Agreement check while we are here.
        let fast = native.score(&state, cand, &bank, 1.2, false);
        let slow = reference_scores(&state, cand, &bank, 1.2, false);
        for core in 0..cfg.host.cores {
            assert!((fast.ol_after()[core] - slow.ol_after()[core]).abs() < 1e-9);
            assert!((fast.ic_after()[core] - slow.ic_after()[core]).abs() < 1e-9);
        }
        if let Some(xla) = xla.as_mut() {
            let x = xla.score(&state, cand, &bank, 1.2, false);
            for core in 0..cfg.host.cores {
                assert!((fast.ol_after()[core] - x.ol_after()[core]).abs() < 1e-3);
                assert!((fast.ic_after()[core] - x.ic_after()[core]).abs() < 1e-3);
            }
        }
    }
    if xla.is_some() {
        println!("\nagreement: incremental, reference, and XLA scores match on all sampled states");
    } else {
        println!("\nagreement: incremental and reference scores match (XLA not compared)");
    }
    Ok(())
}

#![allow(dead_code)]

//! Shared setup for the figure benches.

use vmcd::config::Config;
use vmcd::profiling::ProfileBank;

/// Quick-mode seeds (VMCD_BENCH_QUICK=1 uses one seed, else three).
pub fn seeds() -> Vec<u64> {
    if std::env::var("VMCD_BENCH_QUICK").as_deref() == Ok("1") {
        vec![42]
    } else {
        vec![42, 43, 44]
    }
}

/// Benchmark config: the paper's testbed, deterministic noise seed.
pub fn config() -> Config {
    Config::default()
}

/// The shared profile bank (cached to disk so repeated bench runs skip the
/// profiling phase).
pub fn bank(cfg: &Config) -> ProfileBank {
    ProfileBank::load_or_generate(cfg, Some("results/profiles.json"))
}

/// Output directory for CSV mirrors.
pub fn out_dir() -> std::path::PathBuf {
    std::path::PathBuf::from("results")
}

//! Dispatch ranking: per-host scalar pick loop vs one batched
//! `ArrivalPolicy::rank` call over the flat SoA `SummaryMatrix`.
//!
//! The scalar side is the frozen pre-matrix path (`dispatch::scalar`):
//! one full `Vec<HostSummary>` scan per arrival, with the bus's live
//! per-pick updates (`resident += 1`, `est_cpu_load += demand[cpu]`)
//! replayed between picks. The batched side ranks the whole burst in
//! one `rank` call over dense f64 columns — the cache-friendly layout
//! the score-matrix redesign buys. Both sides must agree pick-for-pick
//! (asserted here; bit-for-bit gated by the parity proptest).
//!
//! Emits `BENCH_dispatch.json` so the dispatch hot path has a recorded
//! perf trajectory (the acceptance bar: batched beats scalar at 1024
//! hosts, burst ≥ 8).

mod common;

use vmcd::bench::Bench;
use vmcd::cluster::dispatch::{scalar, ArrivalBatch, Dispatcher};
use vmcd::cluster::{HostSummary, SummaryMatrix};
use vmcd::profiling::ProfileBank;
use vmcd::util::json::Json;
use vmcd::util::rng::Rng;
use vmcd::vmcd::scheduler::ScoreBuf;
use vmcd::workloads::{WorkloadClass, ALL_CLASSES};

const HOST_CORES: usize = 12;

/// Random published summaries: what the last refresh left on the bus.
fn random_summaries(hosts: usize, rng: &mut Rng) -> Vec<HostSummary> {
    (0..hosts)
        .map(|_| HostSummary {
            resident: rng.below(8),
            busy_cores: rng.below(HOST_CORES + 1),
            max_wi: rng.range(0.0, 3.0),
            est_cpu_load: rng.range(0.0, HOST_CORES as f64),
            ..HostSummary::default()
        })
        .collect()
}

/// One scalar pick per arrival with the bus's live updates in between —
/// the per-host dispatch loop the batched path replaces.
fn scalar_drive(
    d: Dispatcher,
    live: &mut [HostSummary],
    classes: &[WorkloadClass],
    bank: &ProfileBank,
    rng: &mut Rng,
    picks: &mut Vec<usize>,
) {
    picks.clear();
    let mut cursor = 0usize;
    for &class in classes {
        let h = match d {
            Dispatcher::RoundRobin => scalar::round_robin(&mut cursor, live),
            Dispatcher::LeastLoaded => scalar::least_loaded(live),
            Dispatcher::LowestInterference => scalar::lowest_interference(live),
            Dispatcher::Random => scalar::random(live, rng),
            _ => unreachable!("no scalar counterpart for {}", d.name()),
        };
        live[h].resident += 1;
        live[h].est_cpu_load += bank.u[class.index()][0];
        picks.push(h);
    }
}

/// Undo `scalar_drive`'s live updates so the next iteration starts from
/// the same summaries without re-cloning the whole vector.
fn scalar_undo(
    live: &mut [HostSummary],
    classes: &[WorkloadClass],
    bank: &ProfileBank,
    picks: &[usize],
) {
    for (&h, &class) in picks.iter().zip(classes) {
        live[h].resident -= 1;
        live[h].est_cpu_load -= bank.u[class.index()][0];
    }
}

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let quick = std::env::var("VMCD_BENCH_QUICK").as_deref() == Ok("1");
    let mut b = Bench::new();
    let mut rows: Vec<Json> = Vec::new();

    for &hosts in &[256usize, 1024, 4096] {
        for &burst in &[1usize, 8, 32] {
            b.section(&format!("{hosts} hosts × burst {burst}"));
            let mut rng = Rng::new(42);
            let summaries = random_summaries(hosts, &mut rng);
            let classes: Vec<WorkloadClass> =
                (0..burst).map(|_| *rng.pick(&ALL_CLASSES)).collect();
            let matrix = SummaryMatrix::from_summaries(&summaries, HOST_CORES);
            let mut batch = ArrivalBatch::default();
            for &class in &classes {
                batch.push_class(class, &bank);
            }

            for d in [
                Dispatcher::RoundRobin,
                Dispatcher::LeastLoaded,
                Dispatcher::LowestInterference,
                Dispatcher::Random,
            ] {
                // Agreement check first: same seeds, identical picks.
                let mut want = Vec::new();
                let mut live = summaries.clone();
                scalar_drive(d, &mut live, &classes, &bank, &mut Rng::new(7), &mut want);
                let mut policy = d.build();
                let mut scratch = ScoreBuf::default();
                let mut got = Vec::new();
                policy.rank(&matrix, &batch, &mut scratch, &mut Rng::new(7), &mut got);
                assert_eq!(got, want, "{} batched != scalar", d.name());

                let mut live = summaries.clone();
                let mut picks = Vec::with_capacity(burst);
                let mut rng_s = Rng::new(7);
                let scalar_r = b
                    .run(&format!("scalar/{}/h{hosts}/b{burst}", d.name()), || {
                        scalar_drive(d, &mut live, &classes, &bank, &mut rng_s, &mut picks);
                        std::hint::black_box(&picks);
                        scalar_undo(&mut live, &classes, &bank, &picks);
                    })
                    .clone();

                let mut policy = d.build();
                let mut rng_b = Rng::new(7);
                let batched_r = b
                    .run(&format!("batched/{}/h{hosts}/b{burst}", d.name()), || {
                        policy.rank(&matrix, &batch, &mut scratch, &mut rng_b, &mut got);
                        std::hint::black_box(&got);
                    })
                    .clone();

                rows.push(Json::from_pairs(vec![
                    ("policy", Json::Str(d.name().into())),
                    ("hosts", Json::Num(hosts as f64)),
                    ("burst", Json::Num(burst as f64)),
                    ("scalar_ms", Json::Num(scalar_r.mean_ms())),
                    ("scalar_p50_ms", Json::Num(scalar_r.p50_ms())),
                    ("batched_ms", Json::Num(batched_r.mean_ms())),
                    ("batched_p50_ms", Json::Num(batched_r.p50_ms())),
                    (
                        "speedup",
                        Json::Num(scalar_r.mean_ms() / batched_r.mean_ms().max(1e-12)),
                    ),
                ]));
            }

            // Vector policies have no scalar counterpart: record the
            // batched cost so their trajectory starts here too.
            for d in [
                Dispatcher::DotProduct,
                Dispatcher::CosineSimilarity,
                Dispatcher::NormBasedGreedy,
            ] {
                let mut policy = d.build();
                let mut scratch = ScoreBuf::default();
                let mut out = Vec::with_capacity(burst);
                let mut rng_v = Rng::new(7);
                let r = b
                    .run(&format!("batched/{}/h{hosts}/b{burst}", d.name()), || {
                        policy.rank(&matrix, &batch, &mut scratch, &mut rng_v, &mut out);
                        std::hint::black_box(&out);
                    })
                    .clone();
                rows.push(Json::from_pairs(vec![
                    ("policy", Json::Str(d.name().into())),
                    ("hosts", Json::Num(hosts as f64)),
                    ("burst", Json::Num(burst as f64)),
                    ("scalar_ms", Json::Null),
                    ("scalar_p50_ms", Json::Null),
                    ("batched_ms", Json::Num(r.mean_ms())),
                    ("batched_p50_ms", Json::Num(r.p50_ms())),
                    ("speedup", Json::Null),
                ]));
            }
        }
    }

    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("dispatch".into())),
        ("host_cores", Json::Num(HOST_CORES as f64)),
        ("quick", Json::Bool(quick)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_dispatch.json", doc.pretty() + "\n")?;
    println!("\nwrote BENCH_dispatch.json ({} rows)", doc.field("rows")?.as_arr().unwrap().len());
    Ok(())
}

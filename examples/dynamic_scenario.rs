//! The dynamic scenario (§V-C.3 / Figs. 4-6): 24 resident VMs activating
//! in 6- or 12-job batches. Shows the CPU-consumption time series — RRS
//! reserves the whole server continuously; the dynamic schedulers track
//! the active-batch envelope by consolidating idle VMs onto core 0.
//!
//! ```sh
//! cargo run --release --example dynamic_scenario [-- --batch 6]
//! ```

use vmcd::config::Config;
use vmcd::profiling::ProfileBank;
use vmcd::report;
use vmcd::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let batch = args.opt_usize("batch", 6)?;
    let cfg = Config::default();
    let bank = ProfileBank::generate(&cfg);

    let fig = report::fig45(&cfg, &bank, batch, cfg.sim.seed)?;
    println!("{}", fig.render());
    fig.write_csv(std::path::Path::new("results"))?;

    let fig6 = report::fig6(&cfg, &bank, &[cfg.sim.seed])?;
    println!("{}", fig6.render());
    Ok(())
}

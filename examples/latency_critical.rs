//! The latency-critical heavy scenario (§V-C.2 / Fig. 3): many low-load
//! latency-critical services plus a few batch/streaming workloads.
//! Demonstrates the paper's claim that latency-critical VMs can be
//! consolidated without breaking QoS if interference is taken into account.
//!
//! ```sh
//! cargo run --release --example latency_critical [-- --sr 2.0]
//! ```

use vmcd::config::Config;
use vmcd::profiling::ProfileBank;
use vmcd::scenarios::{latency, run_scenario};
use vmcd::util::cli::Args;
use vmcd::vmcd::scheduler::Policy;
use vmcd::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let sr = args.opt_f64("sr", 2.0)?;
    let cfg = Config::default();
    let bank = ProfileBank::generate(&cfg);
    let spec = latency::build(cfg.host.cores, sr, cfg.sim.seed)?;

    println!("latency-critical heavy scenario, SR = {sr} ({} VMs)", spec.vms.len());
    for (class, n) in spec.class_histogram() {
        println!("  {:<14} × {n}", class.name());
    }
    println!();

    let mut base: Option<vmcd::scenarios::ScenarioResult> = None;
    for policy in Policy::ALL {
        let r = run_scenario(&cfg, &spec, policy, &bank)?;
        // QoS view: the mean performance of ONLY the latency-critical VMs.
        let lc: Vec<f64> = r
            .per_class_perf
            .iter()
            .filter(|(c, _)| {
                vmcd::workloads::catalog::spec_of(*c).perf.kind
                    == WorkloadKind::LatencyCritical
            })
            .map(|&(_, p)| p)
            .collect();
        let lc_perf = lc.iter().sum::<f64>() / lc.len().max(1) as f64;
        match &base {
            None => {
                println!(
                    "  {:<4} perf {:.3} (LC-only {:.3}), {:.3} core-h",
                    policy.name(),
                    r.avg_perf,
                    lc_perf,
                    r.core_hours
                );
                base = Some(r);
            }
            Some(b) => {
                println!(
                    "  {:<4} perf {:.3} (LC-only {:.3}), {:.3} core-h \
                     [{:+.1}% perf, {:+.1}% CPU time vs RRS]",
                    policy.name(),
                    r.avg_perf,
                    lc_perf,
                    r.core_hours,
                    (r.perf_vs(b) - 1.0) * 100.0,
                    -r.cpu_saving_vs(b) * 100.0,
                );
            }
        }
    }
    println!(
        "\npaper's Fig. 3 shape: ≥30% CPU-time saving (up to ~50% for IAS at \
         SR=1)\nwith latency-critical degradation bounded (≤10%)."
    );
    Ok(())
}

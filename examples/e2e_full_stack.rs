//! End-to-end full-stack driver: proves all three layers compose on a real
//! workload.
//!
//! * **L3** — the VMCd daemon schedules a random-scenario VM population on
//!   the simulated 12-core host, with the placement scores computed by the
//!   **XLA scoring backend** (the AOT-compiled Pallas kernel via PJRT).
//! * **L1/L2** — the CPU-intensive VMs do *real compute*: every
//!   blackscholes VM prices 65 536 options per executed batch and every
//!   jacobi VM relaxes a 256×256 grid (10 fused sweeps/call), both through
//!   the compiled Pallas kernels. The jacobi residual is logged as the
//!   convergence curve.
//!
//! Reports the paper's headline metric — CPU-time saving vs the RRS
//! baseline at bounded performance cost — plus kernel-health receipts
//! (checksums finite, residuals decreasing).
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_full_stack
//! ```

use vmcd::config::Config;
use vmcd::hostsim::{SimEngine, Vm, VmId, VmState};
use vmcd::profiling::ProfileBank;
use vmcd::runtime::compute::{BlackscholesWork, JacobiWork};
use vmcd::runtime::{Runtime, XlaScoring};
use vmcd::util::cli::Args;
use vmcd::vmcd::scheduler::{self, Policy};
use vmcd::vmcd::Daemon;
use vmcd::workloads::WorkloadClass;
use std::collections::BTreeMap;

/// Execute one real kernel batch per this many virtual seconds of batch-VM
/// progress (keeps the demo snappy while still running hundreds of real
/// PJRT executions).
const VIRT_SECONDS_PER_BATCH: f64 = 10.0;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let sr = args.opt_f64("sr", 1.0)?;
    let policy = Policy::parse(&args.opt_or("policy", "ias"))?;
    let cfg = Config::default();

    println!("== e2e full stack: {} @ SR {sr} on the simulated X5650 host ==", policy.name());

    // ---- profiling phase ----
    let bank = ProfileBank::generate(&cfg);
    println!("profiled {} classes; Eq.5 threshold {:.3}", bank.n(), bank.mean_slowdown());

    // ---- PJRT runtimes: one for scoring, one for workload compute ----
    let scoring_rt = Runtime::new()?;
    println!("PJRT platform: {}", scoring_rt.platform());
    let xla_backend = Box::new(XlaScoring::new(scoring_rt)?);
    let mut compute_rt = Runtime::new()?;
    compute_rt.prepare("blackscholes")?;
    compute_rt.prepare("jacobi")?;

    // ---- build the scenario ----
    let spec = vmcd::scenarios::random::build(cfg.host.cores, sr, cfg.sim.seed)?;
    let vms: Vec<Vm> = spec
        .vms
        .iter()
        .enumerate()
        .map(|(i, t)| Vm::new(VmId(i as u32), t.class, t.arrival, t.activity.clone()))
        .collect();
    println!("scenario {}: {} VMs", spec.name, vms.len());

    // Real-compute state per CPU-intensive VM.
    let mut bs_work: BTreeMap<VmId, BlackscholesWork> = BTreeMap::new();
    let mut jc_work: BTreeMap<VmId, JacobiWork> = BTreeMap::new();
    let mut progress_credit: BTreeMap<VmId, f64> = BTreeMap::new();
    for vm in &vms {
        match vm.class {
            WorkloadClass::Blackscholes => {
                bs_work.insert(vm.id, BlackscholesWork::new(vm.id.0 as u64 + 100));
            }
            WorkloadClass::Jacobi => {
                jc_work.insert(vm.id, JacobiWork::new(vm.id.0 as u64 + 200));
            }
            _ => {}
        }
    }

    // ---- drive engine + daemon with the XLA scheduler ----
    let sched = scheduler::build_with_backend(
        policy,
        &bank,
        cfg.sched.ras_threshold,
        cfg.sched.ias_threshold,
        xla_backend,
    );
    let mut engine = SimEngine::new(cfg.clone(), vms);
    let mut daemon = Daemon::new(cfg.sched.clone(), sched, cfg.host.cores);

    #[allow(clippy::disallowed_methods)] // process edge: examples report wall time
    let wall_start = std::time::Instant::now();
    let mut kernel_batches = 0u64;
    let mut residual_log: Vec<(f64, f64)> = Vec::new();

    loop {
        for id in engine.process_arrivals() {
            daemon.on_arrival(&mut engine, id)?;
        }
        daemon.step(&mut engine)?;

        // Record per-VM progress before the tick to credit real compute.
        let before: BTreeMap<VmId, f64> = engine
            .vms
            .iter()
            .filter(|vm| vm.state == VmState::Running)
            .map(|vm| (vm.id, vm.work_done))
            .collect();
        engine.step();

        // Real compute: batch VMs execute kernel batches proportional to
        // the simulated progress the contention model granted them.
        for vm in &engine.vms {
            let Some(&w0) = before.get(&vm.id) else { continue };
            let delta = vm.work_done - w0;
            if delta <= 0.0 {
                continue;
            }
            let credit = progress_credit.entry(vm.id).or_insert(0.0);
            *credit += delta;
            while *credit >= VIRT_SECONDS_PER_BATCH {
                *credit -= VIRT_SECONDS_PER_BATCH;
                if let Some(work) = bs_work.get_mut(&vm.id) {
                    let checksum = work.run_batch(&mut compute_rt)?;
                    anyhow::ensure!(checksum.is_finite());
                    kernel_batches += 1;
                } else if let Some(work) = jc_work.get_mut(&vm.id) {
                    let resid = work.run_batch(&mut compute_rt)?;
                    residual_log.push((engine.t, resid));
                    kernel_batches += 1;
                }
            }
        }

        if engine.all_batch_done() && !engine.arrivals_pending() && engine.t >= spec.min_duration
        {
            break;
        }
        if engine.t >= cfg.sim.max_time {
            break;
        }
    }
    let wall = wall_start.elapsed();

    // ---- RRS baseline for the headline metric (pure simulation) ----
    let baseline = vmcd::scenarios::run_scenario(&cfg, &spec, Policy::Rrs, &bank)?;

    let perfs: Vec<f64> = engine
        .vms
        .iter()
        .filter_map(|vm| vm.normalized_perf())
        .collect();
    let avg_perf = perfs.iter().sum::<f64>() / perfs.len().max(1) as f64;
    let core_hours = engine.ledger.core_hours();

    println!("\n== results ==");
    println!("virtual time        : {:.0} s (wall {:.2} s)", engine.t, wall.as_secs_f64());
    println!("avg performance     : {:.3} (RRS baseline {:.3})", avg_perf, baseline.avg_perf);
    println!(
        "CPU time consumed   : {:.3} core-h vs RRS {:.3} -> {:.1}% saving",
        core_hours,
        baseline.core_hours,
        (1.0 - core_hours / baseline.core_hours) * 100.0
    );
    println!("scheduler re-pins   : {}", engine.ledger.repin_count);
    println!(
        "XLA scoring calls   : every placement decision went through PJRT"
    );
    println!("real kernel batches : {kernel_batches} PJRT executions");
    for (id, w) in &bs_work {
        println!(
            "  blackscholes vm{:<3} {} batches, last checksum {:.1}",
            id.0, w.batches_done, w.last_checksum
        );
    }
    for (id, w) in &jc_work {
        println!(
            "  jacobi       vm{:<3} {} sweeps, final residual {:.4}",
            id.0, w.sweeps_done, w.last_residual
        );
    }
    if residual_log.len() >= 2 {
        println!("\njacobi convergence (virtual-time, residual):");
        let stride = (residual_log.len() / 8).max(1);
        for (t, r) in residual_log.iter().step_by(stride) {
            println!("  t={t:>6.0}s residual={r:.4}");
        }
        anyhow::ensure!(
            residual_log.last().unwrap().1 < residual_log.first().unwrap().1,
            "jacobi residual must decrease"
        );
    }
    anyhow::ensure!(kernel_batches > 0, "no real compute executed");
    println!("\ne2e OK: L3 rust daemon + L2 XLA graphs + L1 Pallas kernels composed.");
    Ok(())
}

//! Quickstart: profile the workload catalog, run one scenario under IAS,
//! and print the paper's two headline metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use vmcd::config::Config;
use vmcd::profiling::ProfileBank;
use vmcd::report;
use vmcd::scenarios::{random, run_scenario};
use vmcd::vmcd::scheduler::Policy;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();

    // 1. Offline profiling phase (paper §IV-A): isolated + pairwise
    //    co-pinned runs produce the S (slowdown) and U (utilisation)
    //    matrices the schedulers consume.
    println!("profiling the workload catalog (isolated + pairwise co-runs)…");
    let bank = ProfileBank::generate(&cfg);
    println!(
        "  {} classes; mean pairwise slowdown (Eq. 5 IAS threshold): {:.3}\n",
        bank.n(),
        bank.mean_slowdown()
    );

    // 2. One random scenario (paper §V-C.1) at SR = 1 under each policy.
    println!("random scenario, SR = 1.0 (12 VMs on the 12-core host):");
    let spec = random::build(cfg.host.cores, 1.0, cfg.sim.seed)?;
    let mut rrs_baseline = None;
    for policy in Policy::ALL {
        let r = run_scenario(&cfg, &spec, policy, &bank)?;
        let (perf_note, hours_note) = match &rrs_baseline {
            None => ("".to_string(), "".to_string()),
            Some(base) => {
                let b: &vmcd::scenarios::ScenarioResult = base;
                (
                    format!(" ({:+.1}% vs RRS)", (r.perf_vs(b) - 1.0) * 100.0),
                    format!(" ({:+.1}% vs RRS)", -r.cpu_saving_vs(b) * 100.0),
                )
            }
        };
        println!(
            "  {:<4} perf {:.3}{:<18} CPU time {:.3} core-h{}",
            policy.name(),
            r.avg_perf,
            perf_note,
            r.core_hours,
            hours_note
        );
        if policy == Policy::Rrs {
            rrs_baseline = Some(r);
        }
    }

    // 3. Table I: the perf-counter → memory-bandwidth path.
    println!("\n{}", report::table1(&cfg)?);
    Ok(())
}

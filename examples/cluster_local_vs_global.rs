//! Local vs global consolidation across a small cluster — the paper's
//! §VI future-work experiment and its §III argument made runnable.
//!
//! * **local-vmcd**: least-loaded dispatch + a per-host VMCd daemon (IAS)
//!   re-pinning locally; zero migrations.
//! * **global-migration**: a centralized consolidator with full cluster
//!   knowledge that drains lightly-loaded hosts via live migration
//!   (downtime + transfer load + abort risk under load).
//!
//! ```sh
//! cargo run --release --example cluster_local_vs_global [-- --hosts 3 --sr 1.8]
//! ```

use vmcd::cluster::{ClusterSim, ClusterSpec, Strategy};
use vmcd::config::Config;
use vmcd::profiling::ProfileBank;
use vmcd::scenarios::random;
use vmcd::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let hosts = args.opt_usize("hosts", 3)?;
    let cfg = Config::default();
    let bank = ProfileBank::generate(&cfg);

    println!(
        "{:<6} {:<18} {:>7} {:>12} {:>12} {:>12}",
        "SR/host", "strategy", "perf", "core-hours", "host-hours", "migrations"
    );
    for sr in [0.6, 1.2, 1.8] {
        // Cluster-wide population: hosts × 12 cores × sr.
        let scen = random::build(hosts * cfg.host.cores, sr, cfg.sim.seed)?;
        for strategy in [Strategy::LocalVmcd, Strategy::GlobalMigration] {
            let spec = ClusterSpec::new(hosts, strategy);
            let sim = ClusterSim::new(spec, &scen, &bank);
            let r = sim.run(&bank, scen.min_duration)?;
            println!(
                "{:<6} {:<18} {:>7.3} {:>12.3} {:>12.3} {:>7} ({} failed)",
                sr,
                strategy.name(),
                r.avg_perf,
                r.core_hours,
                r.host_hours,
                r.migrations_started,
                r.migrations_failed
            );
        }
    }
    println!(
        "\npaper §III: under cluster-wide oversubscription, migration-based\n\
         global consolidation pays downtime + transfer + abort costs while\n\
         the local per-host approach keeps optimising for free."
    );

    // Sharded stepping: native-backend hosts are `Send`, so the cluster
    // can step them on worker threads — results are bit-identical.
    let scen = random::build(hosts * cfg.host.cores, 1.2, cfg.sim.seed)?;
    let mut results = Vec::new();
    for threads in [0usize, 4] {
        let mut spec = ClusterSpec::new(hosts, Strategy::LocalVmcd);
        spec.shard_threads = threads;
        let wall = std::time::Instant::now();
        let r = ClusterSim::new(spec, &scen, &bank).run(&bank, scen.min_duration)?;
        println!(
            "shard_threads={threads}: perf {:.3}, core-hours {:.3} ({} ms wall)",
            r.avg_perf,
            r.core_hours,
            wall.elapsed().as_millis()
        );
        results.push(r);
    }
    assert_eq!(
        results[0].avg_perf.to_bits(),
        results[1].avg_perf.to_bits(),
        "sharded stepping must be bit-identical"
    );
    Ok(())
}

//! Local vs global consolidation across a small cluster — the paper's
//! §VI future-work experiment and its §III argument made runnable, now
//! entirely through the cluster event bus:
//!
//! * **local-vmcd**: an arrival policy dispatches each VM off the bus's
//!   published host summaries; a per-host VMCd daemon (IAS) re-pins
//!   locally; zero migrations.
//! * **global-migration**: a centralized consolidator plans from the
//!   same summaries and publishes `ClusterEvent::Migrate`s — each a
//!   departure on the source plus a delayed, downtime-paused arrival on
//!   the destination (transfer load + abort risk under load).
//!
//! ```sh
//! cargo run --release --example cluster_local_vs_global \
//!     [-- --hosts 3 --dispatcher least-loaded --workers 4 --actuation deferred:4]
//! ```

use vmcd::cluster::{ClusterSpec, Dispatcher, StepMode, Strategy};
use vmcd::config::Config;
use vmcd::profiling::ProfileBank;
use vmcd::scenarios::{self, run_cluster};
use vmcd::util::cli::Args;
use vmcd::vmcd::ActuationSpec;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let hosts = args.opt_usize("hosts", 3)?;
    // `--dispatcher` and `--actuation` go through the same parses the
    // CLI uses: a typo errors out listing the valid names.
    let dispatcher = Dispatcher::parse(&args.opt_or("dispatcher", "least-loaded"))?;
    let actuation = ActuationSpec::parse(&args.opt_or("actuation", "inline"))?;
    let workers = args.opt_usize("workers", 4)?;
    let cfg = Config::default();
    let bank = ProfileBank::generate(&cfg);

    println!(
        "{:<6} {:<18} {:>7} {:>12} {:>12} {:>12}",
        "SR/host", "strategy", "perf", "core-hours", "host-hours", "migrations"
    );
    for sr in [0.6, 1.2, 1.8] {
        // Cluster-wide population: hosts × 12 cores × sr.
        let scen = scenarios::random::build(hosts * cfg.host.cores, sr, cfg.sim.seed)?;
        for strategy in [Strategy::LocalVmcd, Strategy::GlobalMigration] {
            let mut spec = ClusterSpec::new(hosts, strategy);
            spec.dispatcher = dispatcher;
            spec.actuation = actuation;
            let r = run_cluster(&spec, &scen, &bank)?;
            println!(
                "{:<6} {:<18} {:>7.3} {:>12.3} {:>12.3} {:>7} ({} failed, {} events)",
                sr,
                strategy.name(),
                r.avg_perf,
                r.core_hours,
                r.host_hours,
                r.migrations_started,
                r.migrations_failed,
                r.events_routed
            );
        }
    }
    println!(
        "\npaper §III: under cluster-wide oversubscription, migration-based\n\
         global consolidation pays downtime + transfer + abort costs while\n\
         the local per-host approach keeps optimising for free."
    );

    // Step modes: the persistent pool owns native hosts on worker
    // threads for the whole run; the per-tick scope re-spawns each tick;
    // single keeps everything on the caller thread. All bit-identical.
    let scen = scenarios::random::build(hosts * cfg.host.cores, 1.2, cfg.sim.seed)?;
    let mut results = Vec::new();
    for mode in [
        StepMode::Single,
        StepMode::Scoped(workers),
        StepMode::Pool(workers),
    ] {
        let mut spec = ClusterSpec::new(hosts, Strategy::LocalVmcd);
        spec.dispatcher = dispatcher;
        spec.actuation = actuation;
        spec.step_mode = mode;
        #[allow(clippy::disallowed_methods)] // process edge: examples report wall time
        let wall = std::time::Instant::now();
        let r = run_cluster(&spec, &scen, &bank)?;
        println!(
            "step-mode {:<7}: perf {:.3}, core-hours {:.3} ({} ms wall)",
            mode.name(),
            r.avg_perf,
            r.core_hours,
            wall.elapsed().as_millis()
        );
        results.push(r);
    }
    for r in &results[1..] {
        assert_eq!(
            results[0].avg_perf.to_bits(),
            r.avg_perf.to_bits(),
            "all step modes must be bit-identical"
        );
    }
    Ok(())
}

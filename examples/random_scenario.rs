//! The paper's random scenario (§V-C.1 / Fig. 2) end to end: sweep the
//! subscription ratio and print performance + CPU time per scheduler.
//!
//! ```sh
//! cargo run --release --example random_scenario [-- --seed 7]
//! ```

use vmcd::config::Config;
use vmcd::profiling::ProfileBank;
use vmcd::report;
use vmcd::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = Config::default();
    cfg.sim.seed = args.opt_u64("seed", cfg.sim.seed)?;
    let seeds = vec![cfg.sim.seed, cfg.sim.seed + 1];

    let bank = ProfileBank::generate(&cfg);
    let fig = report::fig2(&cfg, &bank, &seeds)?;
    println!("{}", fig.render());
    fig.write_csv(std::path::Path::new("results"))?;
    println!("CSV mirror: results/fig2.csv");

    // The paper's headline: consolidation saves CPU time at bounded
    // performance cost even under oversubscription.
    for row in &fig.rows {
        if row.policy == vmcd::vmcd::scheduler::Policy::Ias {
            println!(
                "IAS @ SR {}: {:.1}% CPU-time saving, {:+.1}% perf vs RRS",
                row.sr,
                row.cpu_saving_vs_rrs * 100.0,
                (row.perf_vs_rrs - 1.0) * 100.0
            );
        }
    }
    Ok(())
}
